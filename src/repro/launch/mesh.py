"""Production mesh: one TPU v5e pod = (data=16, model=16) = 256 chips;
multi-pod adds a leading pod axis (2 pods = 512 chips).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state. When the host exposes
more placeholder devices than the mesh needs (the dry-run forces 512), the
single-pod mesh takes the first 256.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count=512 before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel axes: ('pod','data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_shards(mesh: Mesh) -> int:
    """Number of data shards a serving engine partitions its slot axis
    (and page pool) into: the product of the non-model axes."""
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)], dtype=int))


def make_serving_mesh(shape: tuple[int, int] = (2, 2), *,
                      devices=None) -> Mesh:
    """(data, model) mesh for a sharded ``StreamingEngine`` over whatever
    devices exist — real accelerators in production, forced host-platform
    devices in tests/CI (set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` BEFORE the
    first jax import). Unlike the production mesh this takes any shape
    that fits the device count, so a (2, 2) mesh exercises real
    cross-shard paths on one host."""
    n = int(np.prod(shape))
    devices = list(jax.devices() if devices is None else devices)
    if len(devices) < n:
        raise RuntimeError(
            f"serving mesh {tuple(shape)} needs {n} devices, have "
            f"{len(devices)} — on a host platform set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} (or more) before "
            f"importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), ("data", "model"))
