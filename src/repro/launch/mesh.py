"""Production mesh: one TPU v5e pod = (data=16, model=16) = 256 chips;
multi-pod adds a leading pod axis (2 pods = 512 chips).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state. When the host exposes
more placeholder devices than the mesh needs (the dry-run forces 512), the
single-pod mesh takes the first 256.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count=512 before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel axes: ('pod','data') on multi-pod, ('data',) otherwise."""
    return tuple(a for a in mesh.axis_names if a != "model")
