"""Per-(arch × input-shape) step builders for the multi-pod dry-run and the
real launchers. Everything is ShapeDtypeStruct-based: no arrays are ever
allocated for the full-size configs (the CPU host could not hold them).

Input shapes (assigned):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (last-token logits)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; sub-quadratic
               attention required: SSM/hybrid run natively, dense/MoE/VLM
               run the sliding-window variant (window 8192), encoder-only
               audio is skipped (no decode step exists)   [DESIGN.md §4]
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch import shardings as sh
from repro.launch.mesh import dp_axes
from repro.models import transformer as tr
from repro.training.optimizer import adam_init
from repro.training.trainer import make_lm_train_step

SHAPES = {
    "train_4k":    dict(seq=4096,   batch=256, kind="train"),
    "prefill_32k": dict(seq=32768,  batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32768,  batch=128, kind="decode"),
    "long_500k":   dict(seq=524288, batch=1,   kind="decode"),
}

# The paper's own Molecular Transformer at pod scale (industrial serving:
# one request stream per data slot, model replicated — an 11M-param model
# does not shard; throughput comes from request parallelism). seq 256 covers
# USPTO SMILES lengths; mt_verify is the speculative verify pass (DL=10).
MT_SHAPES = {
    "mt_train":  dict(seq=256, batch=4096, kind="mt_train"),
    "mt_verify": dict(seq=256, batch=4096, kind="mt_verify", verify=11),
}

SLIDING_WINDOW_LONG = 8192  # beyond-paper variant for dense archs @ 500k


class BuiltStep(NamedTuple):
    fn: Any                 # jit-able function
    inputs: tuple           # ShapeDtypeStruct pytree args
    in_shardings: tuple
    out_shardings: Any      # None = let GSPMD choose
    note: str


def skip_reason(arch: str, shape_name: str) -> str | None:
    cfg = get_config(arch)
    if shape_name in MT_SHAPES:
        return None if cfg.family == "seq2seq" else \
            "mt_* shapes apply to the seq2seq Molecular Transformer only"
    if cfg.family == "seq2seq":
        return "MT uses its own shapes (mt_train / mt_verify)"
    kind = SHAPES[shape_name]["kind"]
    if cfg.family == "audio" and kind == "decode":
        return "encoder-only: no autoregressive decode step (DESIGN.md §4)"
    return None


def _dryrun_cfg(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        # sub-quadratic requirement: sliding-window variant for full-attention
        cfg = dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_LONG)
    return cfg


def _params_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: tr.init(jax.random.PRNGKey(0), cfg,
                                          dtype=dtype))


def input_specs(arch: str, shape_name: str, *, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this pair."""
    return input_specs_for(_dryrun_cfg(arch, shape_name), shape_name,
                           dtype=dtype)


def input_specs_for(cfg: ModelConfig, shape_name: str, *,
                    dtype=jnp.bfloat16) -> dict:
    meta = SHAPES[shape_name]
    S, B, kind = meta["seq"], meta["batch"], meta["kind"]
    sds = jax.ShapeDtypeStruct
    out: dict = {}
    if kind == "train":
        if cfg.family == "audio":
            out["embeddings"] = sds((B, S, cfg.d_model), dtype)
            out["labels"] = sds((B, S), jnp.int32)
        else:
            out["tokens"] = sds((B, S + 1), jnp.int32)
            out["loss_mask"] = sds((B, S + 1), jnp.float32)
        if cfg.family == "vlm":
            out["memory"] = sds((B, cfg.memory_tokens, cfg.memory_dim), dtype)
    elif kind == "prefill":
        if cfg.family == "audio":
            out["embeddings"] = sds((B, S, cfg.d_model), dtype)
        else:
            out["tokens"] = sds((B, S), jnp.int32)
        if cfg.family == "vlm":
            out["memory"] = sds((B, cfg.memory_tokens, cfg.memory_dim), dtype)
    else:  # decode
        out["tokens"] = sds((B, 1), jnp.int32)
        out["positions"] = sds((B, 1), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: tr.init_cache(cfg, B, S, dtype=dtype))
    return out


def build_step(arch: str, shape_name: str, mesh: Mesh,
               *, dtype=jnp.bfloat16, remat: bool = True,
               cfg_override: ModelConfig | None = None,
               fsdp_inference: bool = True,
               verify_tokens: int = 0,
               multidraft: int = 0) -> BuiltStep:
    """``fsdp_inference=False``: tensor-parallel-only params for
    prefill/decode (§Perf pair B). ``verify_tokens=T``: lower the
    speculative verify step (T = DL+1 fed tokens) instead of the 1-token
    serve step (§Perf pair C). ``multidraft=N_d`` (with verify_tokens):
    the beyond-paper single-pass N_d-draft verify (one row per sequence,
    segmented attention) instead of the paper's B·N_d expanded batch."""
    if shape_name in MT_SHAPES:
        return _build_mt_step(arch, shape_name, mesh, dtype=dtype,
                              cfg_override=cfg_override,
                              fsdp_inference=fsdp_inference)
    cfg = cfg_override if cfg_override is not None else _dryrun_cfg(arch, shape_name)
    meta = SHAPES[shape_name]
    S, B, kind = meta["seq"], meta["batch"], meta["kind"]
    params = _params_specs(cfg, dtype)
    p_sh = sh.param_shardings(params, mesh,
                              fsdp=fsdp_inference or kind == "train")
    dp = dp_axes(mesh)
    specs = input_specs_for(cfg, shape_name, dtype=dtype)

    if kind == "train":
        step = make_lm_train_step(cfg, remat=remat)
        opt = jax.eval_shape(adam_init, params)
        o_sh = sh.opt_shardings(opt, params, mesh)
        batch = {k: v for k, v in specs.items()}
        b_sh = sh.batch_shardings(batch, mesh)
        return BuiltStep(
            fn=step, inputs=(params, opt, batch),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            note=f"train {arch} B={B} S={S} remat={remat}")

    if kind == "prefill":
        cache_like = jax.eval_shape(lambda: tr.init_cache(cfg, B, S, dtype=dtype))
        c_sh = sh.cache_shardings(cache_like, cfg, mesh)
        logits_sh = NamedSharding(mesh, P(dp if B % sh._axis_size(mesh, dp) == 0
                                          else None, None))

        if cfg.family == "audio":
            def fn(params, embeddings):
                logits, _ = tr.apply(params, cfg, embeddings=embeddings)
                return logits
            emb = specs["embeddings"]
            return BuiltStep(
                fn=fn, inputs=(params, emb),
                in_shardings=(p_sh, sh.batch_shardings(emb, mesh)),
                out_shardings=NamedSharding(mesh, P(dp, None, None)),
                note=f"encode {arch} B={B} S={S}")

        if cfg.family == "vlm":
            def fn(params, tokens, memory):
                cache = tr.init_cache(cfg, B, S, dtype=dtype)
                return tr.prefill(params, cfg, cache, tokens, memory=memory,
                                  logits_mode="last")
            args = (params, specs["tokens"], specs["memory"])
            in_sh = (p_sh, sh.batch_shardings(specs["tokens"], mesh),
                     sh.batch_shardings(specs["memory"], mesh))
        else:
            def fn(params, tokens):
                cache = tr.init_cache(cfg, B, S, dtype=dtype)
                return tr.prefill(params, cfg, cache, tokens,
                                  logits_mode="last")
            args = (params, specs["tokens"])
            in_sh = (p_sh, sh.batch_shardings(specs["tokens"], mesh))
        return BuiltStep(fn=fn, inputs=args, in_shardings=in_sh,
                         out_shardings=(logits_sh, c_sh),
                         note=f"prefill {arch} B={B} S={S}")

    # decode: one new token against a seq_len cache (serve_step), or the
    # speculative verify pass (T = DL+1 fed tokens) when verify_tokens > 0
    cache = specs["cache"]
    c_sh = sh.cache_shardings(cache, cfg, mesh)
    T = max(1, verify_tokens)
    if multidraft > 0:
        DL = T - 1
        T = 1 + multidraft * DL
        from repro.core.multidraft import build_local_mask
        local_mask = jnp.asarray(build_local_mask(multidraft, DL))
    sds = jax.ShapeDtypeStruct
    tokens_spec = sds((B, T), jnp.int32)
    pos_spec = sds((B, T), jnp.int32)

    if multidraft > 0:
        def fn(params, cache, tokens, positions):
            logits, kv = tr.multidraft_verify_step(
                params, cfg, cache, tokens, positions, local_mask)
            cache = tr.commit_multidraft(
                cfg, cache, kv, jnp.zeros((B,), jnp.int32),
                jnp.full((B,), DL, jnp.int32), positions[:, 0],
                draft_len=DL)
            return logits, cache
    else:
        def fn(params, cache, tokens, positions):
            logits, cache = tr.decode_step(params, cfg, cache, tokens,
                                           positions)
            cache = tr.commit_cache(cfg, cache, jnp.full((B,), T, jnp.int32))
            return logits, cache

    tok_sh = sh.batch_shardings(tokens_spec, mesh)
    pos_sh = sh.batch_shardings(pos_spec, mesh)
    logits_sh = NamedSharding(
        mesh, P(dp if B % sh._axis_size(mesh, dp) == 0 else None, None,
                "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None))
    return BuiltStep(
        fn=fn, inputs=(params, cache, tokens_spec, pos_spec),
        in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, c_sh),
        note=f"serve {arch} B={B} T={T} cache={S}"
             + (f" window={cfg.sliding_window}" if cfg.sliding_window else ""))


# ---------------------------------------------------------------------------
# Molecular Transformer (seq2seq) at pod scale — the paper's model through
# the same dry-run machinery (shapes: MT_SHAPES).


def _build_mt_step(arch: str, shape_name: str, mesh: Mesh, *,
                   dtype=jnp.bfloat16,
                   cfg_override: ModelConfig | None = None,
                   fsdp_inference: bool = True) -> BuiltStep:
    from repro.models import seq2seq as s2s
    from repro.training.trainer import make_seq2seq_train_step

    cfg = cfg_override if cfg_override is not None else get_config(arch)
    meta = MT_SHAPES[shape_name]
    S, B, kind = meta["seq"], meta["batch"], meta["kind"]
    dp = dp_axes(mesh)
    sds = jax.ShapeDtypeStruct
    params = jax.eval_shape(
        lambda: s2s.init(jax.random.PRNGKey(0), cfg, dtype=dtype))
    if kind != "mt_train" and not fsdp_inference:
        # pure request-parallel serving: an 11M-param model replicates —
        # tensor-parallel all-reduces otherwise dominate (EXPERIMENTS §MT)
        p_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), params)
    else:
        p_sh = sh.param_shardings(params, mesh, fsdp=kind == "mt_train")

    if kind == "mt_train":
        from repro.training.optimizer import adam_init

        step = make_seq2seq_train_step(cfg)
        opt = jax.eval_shape(adam_init, params)
        o_sh = sh.opt_shardings(opt, params, mesh)
        batch = {"src": sds((B, S), jnp.int32),
                 "tgt_in": sds((B, S), jnp.int32),
                 "tgt_out": sds((B, S), jnp.int32)}
        b_sh = sh.batch_shardings(batch, mesh)
        return BuiltStep(fn=step, inputs=(params, opt, batch),
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         note=f"mt train {arch} B={B} S={S}")

    # mt_verify: the speculative verify pass (T = DL+1 tokens per sequence)
    T = meta["verify"]

    def mk_cache():
        c = s2s.init_cache(cfg, B, S, dtype=dtype)
        R = cfg.n_layers
        mkv = {"mk": jnp.zeros((R, B, S, cfg.n_heads, cfg.head_dim), dtype),
               "mv": jnp.zeros((R, B, S, cfg.n_heads, cfg.head_dim), dtype)}
        return {"self": c["self"], "cross": mkv}

    cache = jax.eval_shape(mk_cache)
    b_ax = dp if B % sh._axis_size(mesh, dp) == 0 else None
    c_sh = jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, P(None, b_ax, *((None,) * (leaf.ndim - 2)))), cache)

    def fn(params, cache, tokens, positions):
        logits, cache = s2s.decode_step(params, cfg, cache, tokens, positions)
        return logits, cache

    tokens_spec = sds((B, T), jnp.int32)
    pos_spec = sds((B, T), jnp.int32)
    t_sh = sh.batch_shardings(tokens_spec, mesh)
    logits_sh = NamedSharding(mesh, P(b_ax, None, None))
    return BuiltStep(fn=fn, inputs=(params, cache, tokens_spec, pos_spec),
                     in_shardings=(p_sh, c_sh, t_sh, t_sh),
                     out_shardings=(logits_sh, c_sh),
                     note=f"mt verify {arch} B={B} T={T} cache={S}")
