"""Training launcher for the assigned architectures.

On real hardware this launches the pjit'd train step on the production mesh;
on the CPU container it runs reduced configs end-to-end (synthetic token
streams), which is also what the smoke path of the test suite exercises.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 20 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tr
from repro.training.optimizer import adam_init
from repro.training.trainer import make_lm_train_step


def synthetic_batch(cfg, rng, batch: int, seq: int) -> dict:
    if cfg.family == "audio":
        return {
            "embeddings": jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32)),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    out = {
        "tokens": jnp.asarray(
            rng.integers(4, cfg.vocab_size, (batch, seq + 1)), jnp.int32),
        "loss_mask": jnp.ones((batch, seq + 1), jnp.float32),
    }
    if cfg.family == "vlm":
        out["memory"] = jnp.asarray(
            rng.normal(size=(batch, cfg.memory_tokens, cfg.memory_dim))
            .astype(np.float32))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")
    params = tr.init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.2f}M")
    opt = adam_init(params)
    step = jax.jit(make_lm_train_step(cfg, lr=args.lr, remat=args.remat),
                   donate_argnums=(0, 1))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.steps):
        batch = synthetic_batch(cfg, rng, args.batch, args.seq)
        params, opt, metrics = step(params, opt, batch)
        if i % max(1, args.steps // 10) == 0:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['token_accuracy']):.3f} "
                  f"({time.time()-t0:.1f}s)")
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
