"""Sharding assignments for every dry-run input: params (FSDP + tensor
parallel), optimizer state, batches, and decode caches.

Cache layout reminders (leaves carry a leading scan-repeat dim R):
  attn KVCache : k/v (R, B, S, Kv, hd), pos (R, B, S)
  xattn        : mk/mv (R, B, M, H, hd)
  mamba        : conv (R, B, d_conv-1, d_inner), ssm (R, B, d_inner, d_state)
  rwkv         : S (R, B, H, hd, hd), x_tm/x_cm (R, B, d)

Decode caches shard batch over the data axes; the KV sequence dim shards
over 'model' (sequence-sharded cache) because GQA KV heads (8) do not divide
the 16-way model axis — this is what makes decode_32k fit per-chip HBM
(DESIGN §6).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes
from repro.models.attention import KVCache, PagedKVCache
from repro.sharding import rules


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _axis_size(mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _maybe(mesh, dim, axes):
    """axes if dim divisible by their product else None (replicate)."""
    if not axes:
        return None
    return (axes if len(axes) > 1 else axes[0]) \
        if dim % _axis_size(mesh, axes) == 0 else None


def param_shardings(params, mesh: Mesh, *, fsdp: bool = True):
    """fsdp=False keeps params tensor-parallel only (replicated over data):
    the right choice for decode, where a per-step FSDP all-gather would put
    the whole parameter footprint on the ICI every step (§Perf pair B)."""
    return rules.param_shardings(params, mesh,
                                 fsdp_axes=dp_axes(mesh) if fsdp else ())


def serving_param_shardings(params, cfg, mesh: Mesh):
    """Execution-safe tensor-parallel shardings for the serving engines.

    The dry-run rules shard attention projection outputs over 'model'
    whenever the flattened ``heads * head_dim`` axis divides. EXECUTING
    that layout is only safe when the split lands on whole heads: a chunk
    that cuts inside ``head_dim`` reshapes the sharding onto RoPE's
    rotation axis, and that layout splits the rotation pairs across
    devices (the partitioned concatenate along a sharded axis also
    miscompiles on host-platform meshes — see ``StreamingEngine._repl``).
    Q/K/V projections whose head count does not divide the model axis are
    therefore replicated; everything else follows the rules.
    """
    pspecs = rules.param_pspecs(params, mesh, fsdp_axes=())
    model = int(dict(mesh.shape).get(rules.MODEL, 1))
    heads = {"wq": int(getattr(cfg, "n_heads", 1) or 1),
             "wk": int(getattr(cfg, "n_kv_heads", 0)
                       or getattr(cfg, "n_heads", 1) or 1)}
    heads["wv"] = heads["wk"]

    def one(path, spec):
        names = rules._path_names(path)
        parent = names[-2] if len(names) >= 2 else ""
        if parent in heads and heads[parent] % model:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, pspecs)


def opt_shardings(opt_state, params, mesh: Mesh):
    pspec = rules.param_pspecs(params, mesh, fsdp_axes=dp_axes(mesh))
    mu = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspec)
    nu = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspec)
    return type(opt_state)(step=_ns(mesh), mu=mu, nu=nu)


def batch_shardings(batch, mesh: Mesh):
    dp = dp_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return _ns(mesh)
        b = _maybe(mesh, leaf.shape[0], dp)
        return NamedSharding(mesh, P(b, *((None,) * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(one, batch)


def _shard_one_axis(mesh, shape, axis, axes):
    """NamedSharding partitioning exactly one axis (when divisible)."""
    spec = [None] * len(shape)
    spec[axis] = _maybe(mesh, shape[axis], axes)
    return NamedSharding(mesh, P(*spec))


def serving_state_shardings(gstate, mesh: Mesh):
    """Best-effort NamedShardings for a serving ``GroupedState`` (the
    sharded ``StreamingEngine``'s committed-input layout).

    Slot-parallel serving: every per-group ``SessionState`` leaf leads
    with the group's SLOT axis, which shards over the data axes whenever
    the group's slot count divides them — the engine enforces
    divisibility, so the per-slot decode state is genuinely partitioned
    and shard ``s`` owns its slots end to end. The shared cache follows
    the dry-run shardings' ``_maybe`` divisibility contract: paged pools
    shard their PAGE axis (the engine sizes ``n_pages`` divisible by the
    shard count, so the contiguous per-shard page segments of
    ``device_page_plan`` land one segment per data shard), dense KV rows
    shard when the row count divides, and the tiny block tables (plus any
    leaf that does not divide) replicate — replication is always correct
    under SPMD, it just spends interconnect instead of memory."""
    dp = dp_axes(mesh)
    repl = _ns(mesh)

    def group_leaf(leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return repl
        return _shard_one_axis(mesh, leaf.shape, 0, dp)

    def cache_node(node):
        if isinstance(node, PagedKVCache):
            # trailing dims are (pages, ps, Kv, hd) / (pages, ps); a
            # leading scan-repeat dim may or may not be present
            pool = _shard_one_axis(mesh, node.k_pool.shape,
                                   node.k_pool.ndim - 4, dp)
            return PagedKVCache(
                k_pool=pool, v_pool=pool,
                pos=_shard_one_axis(mesh, node.pos.shape,
                                    node.pos.ndim - 2, dp),
                block_tables=repl)
        if isinstance(node, KVCache):
            # trailing dims are (B, S, Kv, hd) / (B, S)
            kv = _shard_one_axis(mesh, node.k.shape, node.k.ndim - 4, dp)
            return KVCache(k=kv, v=kv,
                           pos=_shard_one_axis(mesh, node.pos.shape,
                                               node.pos.ndim - 2, dp))
        return jax.tree_util.tree_map(lambda x: repl, node)

    groups = tuple(jax.tree_util.tree_map(group_leaf, gs)
                   for gs in gstate.groups)
    cache = jax.tree_util.tree_map(
        cache_node, gstate.cache,
        is_leaf=lambda x: isinstance(x, (PagedKVCache, KVCache)))
    return type(gstate)(groups=groups, cache=cache)


def cache_shardings(cache, cfg: ModelConfig, mesh: Mesh):
    """Per-pattern-position cache shardings (tuple aligned with the cache)."""
    dp = dp_axes(mesh)
    out = []
    for kind, c in zip(cfg.layer_pattern, cache):
        if kind == "attn":
            B, S = c.k.shape[1], c.k.shape[2]
            b = _maybe(mesh, B, dp)
            s = _maybe(mesh, S, ("model",))
            kv = _ns(mesh, None, b, s, None, None)
            out.append(KVCache(k=kv, v=kv, pos=_ns(mesh, None, b, s)))
        elif kind == "xattn":
            B, M = c["mk"].shape[1], c["mk"].shape[2]
            b = _maybe(mesh, B, dp)
            h = _maybe(mesh, c["mk"].shape[3], ("model",))
            out.append({"mk": _ns(mesh, None, b, None, h, None),
                        "mv": _ns(mesh, None, b, None, h, None)})
        elif kind == "mamba":
            B = c["conv"].shape[1]
            b = _maybe(mesh, B, dp)
            di = _maybe(mesh, c["ssm"].shape[2], ("model",))
            out.append({"conv": _ns(mesh, None, b, None, di),
                        "ssm": _ns(mesh, None, b, di, None)})
        elif kind == "rwkv":
            B = c["S"].shape[1]
            b = _maybe(mesh, B, dp)
            h = _maybe(mesh, c["S"].shape[2], ("model",))
            out.append({"S": _ns(mesh, None, b, h, None, None),
                        "x_tm": _ns(mesh, None, b, None),
                        "x_cm": _ns(mesh, None, b, None)})
        else:
            raise ValueError(kind)
    return tuple(out)
