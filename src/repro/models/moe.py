"""Mixture-of-Experts FFN with capacity-based dense dispatch.

Used by three assigned architectures:
  - llama4-maverick-400b-a17b : 128 experts, top-1, shared expert
  - phi3.5-moe-42b-a6.6b      : 16 experts,  top-2
  - jamba-v0.1-52b            : 16 experts,  top-2, on every other layer

Distribution: the expert dimension ``E`` is sharded over the ``model`` mesh
axis (expert parallelism); tokens live on ``data``. The einsum-based dispatch
(one-hot combine (T,E,C) against token states) lowers to all-to-all-shaped
collectives under GSPMD, which is what the roofline's collective term tracks.

Capacity: C = ceil(top_k * T / E * capacity_factor); tokens over capacity are
dropped (standard Switch/GShard semantics) and carried by the residual stream
(+ shared expert when configured). An auxiliary load-balance loss and router
z-loss are returned for the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, ffn, ffn_init


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    k_router, k_exp, k_shared = jax.random.split(key, 3)
    d, dff, E = cfg.d_model, m.d_ff, m.n_experts
    exp_keys = jax.random.split(k_exp, E)
    # experts: stacked (E, ...) leaves so the E dim shards over 'model'
    experts = jax.vmap(
        lambda k: ffn_init(k, d, dff, use_bias=False, gated=True, dtype=dtype)
    )(exp_keys)
    p = {
        "router": dense_init(k_router, d, E, use_bias=False, dtype=dtype),
        "experts": experts,
    }
    if m.shared_expert:
        p["shared"] = ffn_init(k_shared, d, dff, use_bias=False, gated=True, dtype=dtype)
    return p


def moe_ffn(p: dict, cfg: ModelConfig, x) -> tuple[jnp.ndarray, dict]:
    """x: (B, T, d) -> (out, aux) with load-balance metrics/losses."""
    m = cfg.moe
    B, T, d = x.shape
    E, k = m.n_experts, m.top_k
    tokens = x.reshape(B * T, d)
    n_tok = B * T

    logits = (tokens @ p["router"]["w"]).astype(jnp.float32)       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                   # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    capacity = max(1, int(k * n_tok / E * m.capacity_factor))

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)           # (N, k, E)
    flat = onehot.reshape(n_tok * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1             # (N*k, E)
    pos = jnp.max(pos_in_expert.reshape(n_tok, k, E), axis=-1)      # (N, k)
    keep = pos < capacity

    # scatter/gather dispatch: O(N·k·d) data movement. (The GShard one-hot
    # einsum form is O(N·k·E·C·d) — quadratic in tokens since C ∝ N — and
    # dominated the compute roofline term in the dry-run; see EXPERIMENTS.md
    # §Perf. The scatter is bit-identical: buffer slots are unique.)
    kept = keep.astype(tokens.dtype)[..., None]                     # (N, k, 1)
    slot = jnp.where(keep, pos, capacity)                           # C = drop
    expert_in = jnp.zeros((E, capacity + 1, d), tokens.dtype)
    expert_in = expert_in.at[gate_idx, slot].add(tokens[:, None, :] * kept)
    expert_in = expert_in[:, :capacity, :]                          # (E, C, d)

    expert_out = jax.vmap(lambda pe, xe: ffn(pe, xe))(p["experts"], expert_in)

    gathered = expert_out[gate_idx, jnp.minimum(slot, capacity - 1)]  # (N,k,d)
    out = jnp.sum(gathered * kept * gate_vals[..., None].astype(tokens.dtype),
                  axis=1)                                           # (N, d)

    if "shared" in p:
        out = out + ffn(p["shared"], tokens)

    # GShard aux load-balance loss + router z-loss
    frac_tokens = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx[:, 0], E), axis=0)) / max(n_tok, 1)
    me = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(me * ce) * m.aux_loss_weight
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))

    aux = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss,
           "moe_dropped_frac": dropped, "moe_top1_frac": frac_tokens}
    return out.reshape(B, T, d), aux
