"""RWKV6 "Finch" time-mix with data-dependent decay (arXiv:2404.05892).

Per head (size ``hd``), with receptance r, key k, value v, decay w, bonus u:

    S_t = diag(exp(-exp(w_t))) S_{t-1} + k_t^T v_t          (hd × hd state)
    o_t = r_t ( S_{t-1} + diag(u) k_t^T v_t )               -- "bonus" term

The decay w_t is *data-dependent* (low-rank LoRA on the token-shifted input),
which is the Finch contribution over RWKV5. Token-shift mixes x_{t-1} into the
r/k/v/w/g projections with learned per-channel interpolation.

TPU adaptation: a ``lax.scan`` over time in chunks of the head-state update —
the state is (B, H, hd, hd), so the arithmetic intensity per step is a rank-1
update; we batch it over (B, H) and let the VPU vectorize over hd×hd. The HLO
is sequence-length independent (one while loop), which is what makes the
524k-token shape lower cheaply. Channel-mix is the standard RWKV squared-relu
FFN and reuses the generic FFN machinery's sharding rules.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, dense, dense_init, norm_init


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv.head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def rwkv_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    H, hd = _heads(cfg)
    keys = jax.random.split(key, 10)
    lora = max(32, d // 16)
    p = {
        # token-shift interpolation weights (per projection)
        "mix": (jax.random.uniform(keys[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "wr": dense_init(keys[1], d, d, use_bias=False, dtype=dtype),
        "wk": dense_init(keys[2], d, d, use_bias=False, dtype=dtype),
        "wv": dense_init(keys[3], d, d, use_bias=False, dtype=dtype),
        "wg": dense_init(keys[4], d, d, use_bias=False, dtype=dtype),
        # data-dependent decay: w_t = w_base + lora
        "w_base": (jnp.zeros((d,)) - 5.0).astype(dtype),
        "w_lora_a": dense_init(keys[5], d, lora, use_bias=False, dtype=dtype),
        "w_lora_b": dense_init(keys[6], lora, d, use_bias=False, dtype=dtype,
                               scale=1.0 / math.sqrt(lora)),
        "u": (jax.random.normal(keys[7], (H, hd)) * 0.1).astype(dtype),
        "wo": dense_init(keys[8], d, d, use_bias=False, dtype=dtype),
        "ln_x": norm_init(d, "layernorm", dtype),  # group-norm over heads, approx LN
    }
    return p


def _projections(p, cfg, x, x_prev):
    """Token-shifted projections. x: (B,T,d); x_prev: (B,T,d) = x shifted by 1."""
    mix = p["mix"]
    xr = x * mix[0] + x_prev * (1 - mix[0])
    xk = x * mix[1] + x_prev * (1 - mix[1])
    xv = x * mix[2] + x_prev * (1 - mix[2])
    xw = x * mix[3] + x_prev * (1 - mix[3])
    xg = x * mix[4] + x_prev * (1 - mix[4])
    r = dense(p["wr"], xr)
    k = dense(p["wk"], xk)
    v = dense(p["wv"], xv)
    g = jax.nn.silu(dense(p["wg"], xg))
    w = p["w_base"] + dense(p["w_lora_b"], jnp.tanh(dense(p["w_lora_a"], xw)))
    decay = jnp.exp(-jnp.exp(w.astype(jnp.float32)))         # (B,T,d) in (0,1)
    return r, k, v, g, decay


def _split_heads(x, H, hd):  # (B,T,d) -> (B,T,H,hd)
    return x.reshape(*x.shape[:-1], H, hd)


def rwkv_mixer(p: dict, cfg: ModelConfig, x, *, state=None, x_last=None,
               lengths=None):
    """Time-mix over a full sequence (train/prefill) or continuation (decode).

    x: (B, T, d). ``state``: (B, H, hd, hd) carried WKV state; ``x_last``:
    (B, d) last token of the previous chunk (token-shift seam). ``lengths``
    masks right-pad steps to identity state updates (decay=1, kv=0) so the
    final state equals the state at each row's true end.
    Returns (out, (state, x_last)).
    """
    B, T, d = x.shape
    H, hd = _heads(cfg)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    if x_last is None:
        x_last = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, decay = _projections(p, cfg, x, x_prev)
    if lengths is not None:
        valid = (jnp.arange(T) < lengths[:, None])[..., None]
        decay = jnp.where(valid, decay, 1.0)   # pad steps: S_t = S_{t-1}
        k = k * valid.astype(k.dtype)          # pad steps: kv increment = 0
    r = _split_heads(r, H, hd).astype(jnp.float32)
    k = _split_heads(k, H, hd).astype(jnp.float32)
    v = _split_heads(v, H, hd).astype(jnp.float32)
    decay = _split_heads(decay, H, hd)
    u = p["u"].astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                              # (B,H,hd) each
        kv = k_t[..., :, None] * v_t[..., None, :]            # (B,H,hd,hd)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, decay))
    state, o = jax.lax.scan(step, state, inputs)
    o = jnp.moveaxis(o, 0, 1).reshape(B, T, d)                # (B,T,d)
    o = apply_norm(p["ln_x"], o.astype(x.dtype), "layernorm")
    out = dense(p["wo"], o * g)
    return out, (state, x[:, -1, :])


# channel-mix (RWKV FFN): squared-relu with token shift ------------------------


def rwkv_channel_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "mix_c": (jax.random.uniform(key, (1, cfg.d_model)) * 0.5 + 0.25).astype(dtype),
        "w_in": dense_init(k1, cfg.d_model, cfg.d_ff, use_bias=False, dtype=dtype),
        "w_out": dense_init(k2, cfg.d_ff, cfg.d_model, use_bias=False, dtype=dtype),
    }


def rwkv_channel_mix(p: dict, x, *, x_last=None):
    B, T, d = x.shape
    if x_last is None:
        x_last = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xk = x * p["mix_c"][0] + x_prev * (1 - p["mix_c"][0])
    h = jnp.square(jax.nn.relu(dense(p["w_in"], xk)))
    return dense(p["w_out"], h), x[:, -1, :]
