"""Decoder-only (and encoder-only) transformer assembled from a repeating
layer-block pattern — one implementation covers all ten assigned families:

  dense GQA (command-r, qwen3, starcoder2, smollm) : pattern ("attn",)
  VLM (llama-3.2-vision)  : ("attn",)*4 + ("xattn",)  — cross-attn every 5th
  hybrid (jamba)          : mamba/attn 7:1 block with MoE every other layer
  MoE (llama4, phi3.5)    : ("attn",) with ffn_pattern "moe"
  SSM (rwkv6)             : ("rwkv",)
  audio encoder (hubert)  : ("attn",), causal=False, embeddings input

The layer stack is a ``lax.scan`` over pattern repeats (stacked params), so
HLO size and compile time are depth-independent — a hard requirement for the
40× multi-pod dry-run on the CPU host.

Decode caches & speculative decoding
------------------------------------
``decode_step`` feeds T = DL+1 tokens (last committed token + draft) and
returns a cache with *per-step checkpoints* for recurrent blocks. The caller
commits the accepted prefix with ``commit_cache(cfg, cache, n_keep)`` where
``n_keep (B,)`` = 1 + accepted draft tokens. Attention KV caches need no
rollback: stale slots (rejected drafts) are always overwritten by the next
verify pass before they can be attended to (positions are masked on the
stored-position array). Recurrent state rollback is the honest cost of the
paper's technique on SSM/hybrid families (DESIGN.md §4).
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.attention import attention, cached_attention, cross_attention
from repro.models.layers import (
    apply_norm, dense, embed, embed_init, ffn, ffn_init, logits_init, norm_init,
    sinusoidal_positions, unembed,
)
from repro.sharding import ctx as shard_ctx


class DecodeContext(NamedTuple):
    """Static per-call context threaded through block application."""
    mode: str                    # "full" | "prefill" | "decode"
    causal: bool = True
    memory: Any = None           # (B, M, memory_dim) frontend embeddings
    memory_mask: Any = None      # (B, M) bool
    lengths: Any = None          # (B,) row lengths (prefill/full with padding)
    positions: Any = None        # (B, T) absolute positions


# ---------------------------------------------------------------------------
# init


def _ffn_init(key, cfg: ModelConfig, kind: str, dtype):
    if kind == "moe":
        return moe_mod.moe_init(key, cfg, dtype=dtype)
    return ffn_init(key, cfg.d_model, cfg.d_ff, use_bias=cfg.use_bias,
                    gated=cfg.gated_ffn, dtype=dtype)


def _block_init(key, cfg: ModelConfig, kind: str, ffn_kind: str, dtype):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    p: dict = {"norm1": norm_init(d, cfg.norm, dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.attn_init(k1, cfg, dtype=dtype)
    elif kind == "xattn":
        p["attn"] = attn_mod.attn_init(k1, cfg, cross=True, dtype=dtype)
        p["xattn_gate"] = jnp.zeros((1,), dtype)  # llama-3.2 gated cross-attn
    elif kind == "mamba":
        p["mamba"] = mamba_mod.mamba_init(k1, cfg, dtype=dtype)
    elif kind == "rwkv":
        p["rwkv"] = rwkv_mod.rwkv_init(k1, cfg, dtype=dtype)
        p["norm2"] = norm_init(d, cfg.norm, dtype)
        p["cmix"] = rwkv_mod.rwkv_channel_init(k2, cfg, dtype=dtype)
        return p
    else:
        raise ValueError(kind)
    p["norm2"] = norm_init(d, cfg.norm, dtype)
    p["ffn"] = _ffn_init(k2, cfg, ffn_kind, dtype)
    return p


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, len(cfg.layer_pattern) + 3)
    params: dict = {}
    if cfg.family != "audio":  # audio consumes frontend embeddings directly
        params["tok"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    blocks = []
    for i, kind in enumerate(cfg.layer_pattern):
        rep_keys = jax.random.split(keys[1 + i], cfg.n_repeats)
        blocks.append(
            jax.vmap(partial(_block_init, cfg=cfg, kind=kind,
                             ffn_kind=cfg.ffn_pattern[i], dtype=dtype))(rep_keys)
        )
    params["blocks"] = tuple(blocks)
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = logits_init(keys[-1], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
               paged=None) -> tuple:
    """Per-pattern-position caches, stacked over repeats (leading axis).

    ``paged``: ``(n_pages, page_size)`` — allocate every "attn" position's
    self-attention cache as a ``PagedKVCache`` (one pool per position,
    stacked over repeats) instead of dense rows; the caller owns page
    mapping (``repro.core.session.PageAllocator``). All paged positions
    share one page-id space: the allocator keeps their block tables
    identical, so a page id addresses the same logical block in every
    position's pool. Recurrent (mamba/rwkv) and cross-attn caches stay
    dense — their per-row state is O(1) in sequence length or written once.
    """

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_repeats,) + a.shape), tree)

    caches = []
    for kind in cfg.layer_pattern:
        if kind == "attn":
            if paged is not None:
                n_pages, page_size = paged
                c = attn_mod.init_paged_kv_cache(
                    cfg, batch, max_len, n_pages=n_pages,
                    page_size=page_size, dtype=dtype)
            else:
                c = attn_mod.init_kv_cache(cfg, batch, max_len, dtype=dtype)
        elif kind == "xattn":
            M = max(cfg.memory_tokens, 1)
            c = {"mk": jnp.zeros((batch, M, cfg.n_heads, cfg.head_dim), dtype),
                 "mv": jnp.zeros((batch, M, cfg.n_heads, cfg.head_dim), dtype)}
        elif kind == "mamba":
            c = mamba_mod.init_mamba_cache(cfg, batch, dtype)
        elif kind == "rwkv":
            H, hd = cfg.d_model // cfg.rwkv.head_dim, cfg.rwkv.head_dim
            c = {"S": jnp.zeros((batch, H, hd, hd), jnp.float32),
                 "x_tm": jnp.zeros((batch, cfg.d_model), dtype),
                 "x_cm": jnp.zeros((batch, cfg.d_model), dtype)}
        else:
            raise ValueError(kind)
        caches.append(stack(c))
    return tuple(caches)


def commit_cache(cfg: ModelConfig, cache: tuple, n_keep) -> tuple:
    """Select recurrent-state checkpoints after speculative verification.

    n_keep: (B,) int32 — number of fed tokens accepted per row (>= 1).
    Checkpointed recurrent leaves have shape (R, B, T+1, ...); we take index
    n_keep along the step axis. Attention/xattn caches pass through.
    """
    idx = jnp.asarray(n_keep, jnp.int32)

    def take_ckpt(a):
        # a: (R, B, T+1, ...) -> (R, B, ...)
        ix = idx.reshape((1,) + idx.shape + (1,) * (a.ndim - 2))
        return jnp.take_along_axis(a, ix.astype(jnp.int32), axis=2).squeeze(2)

    out = []
    for kind, c in zip(cfg.layer_pattern, cache):
        if kind in ("attn", "xattn"):
            out.append(c)
        else:
            out.append(jax.tree_util.tree_map(take_ckpt, c))
    return tuple(out)


# ---------------------------------------------------------------------------
# block application


def _apply_ffn(p, cfg: ModelConfig, kind: str, x):
    if kind == "moe":
        return moe_mod.moe_ffn(p, cfg, x)
    return ffn(p, x), {}


def _mamba_decode_ckpt(p, cfg, cache, x):
    """Sequential decode that also emits per-step state checkpoints."""
    T = x.shape[1]
    conv0, ssm0 = cache["conv"], cache["ssm"]
    convs, ssms = [conv0], [ssm0]
    c = cache
    ys = []
    for t in range(T):  # T = DL+1 is small & static: unrolled is cheapest
        y, c = mamba_mod.mamba_step(p, cfg, c, x[:, t : t + 1, :])
        ys.append(y)
        convs.append(c["conv"])
        ssms.append(c["ssm"])
    out = jnp.concatenate(ys, axis=1)
    ckpt = {"conv": jnp.stack(convs, axis=1), "ssm": jnp.stack(ssms, axis=1)}
    return out, ckpt


def _rwkv_decode_ckpt(p, cfg, cache, x):
    T = x.shape[1]
    S, x_tm, x_cm = cache["S"], cache["x_tm"], cache["x_cm"]
    Ss, xtms, xcms = [S], [x_tm], [x_cm]
    outs = []
    h = x
    for t in range(T):
        xt = h[:, t : t + 1, :]
        n1 = apply_norm_block(p["norm1"], xt, cfg)
        mix_out, (S, x_tm_new) = rwkv_mod.rwkv_mixer(p["rwkv"], cfg, n1, state=S,
                                                     x_last=x_tm)
        x_tm = x_tm_new
        xt = xt + mix_out
        n2 = apply_norm_block(p["norm2"], xt, cfg)
        cm_out, x_cm = rwkv_mod.rwkv_channel_mix(p["cmix"], n2, x_last=x_cm)
        xt = xt + cm_out
        outs.append(xt)
        Ss.append(S)
        xtms.append(x_tm)
        xcms.append(x_cm)
    out = jnp.concatenate(outs, axis=1)
    ckpt = {"S": jnp.stack(Ss, axis=1), "x_tm": jnp.stack(xtms, axis=1),
            "x_cm": jnp.stack(xcms, axis=1)}
    return out, ckpt


def apply_norm_block(p, x, cfg: ModelConfig):
    return apply_norm(p, x, cfg.norm)


def _block_apply(kind: str, ffn_kind: str, p, cfg: ModelConfig, x, cache,
                 dctx: DecodeContext):
    """One layer. Returns (x, aux_losses, new_cache)."""
    aux: dict = {}
    if kind == "rwkv":
        if dctx.mode == "decode":
            return _rwkv_decode_ckpt(p, cfg, cache, x) + (aux,)
        # full / prefill: chunk-free scan over the whole sequence
        n1 = apply_norm_block(p["norm1"], x, cfg)
        if dctx.lengths is not None:  # zero pad positions so state skips them
            valid = (jnp.arange(x.shape[1]) < dctx.lengths[:, None])
            n1 = n1 * valid[..., None].astype(n1.dtype)
        mix_out, (S, _) = rwkv_mod.rwkv_mixer(
            p["rwkv"], cfg, n1,
            state=None if cache is None else cache["S"],
            x_last=None if cache is None else cache["x_tm"],
            lengths=dctx.lengths)
        x = x + mix_out
        n2 = apply_norm_block(p["norm2"], x, cfg)
        cm_out, _ = rwkv_mod.rwkv_channel_mix(p["cmix"], n2)
        x = x + cm_out
        new_cache = None
        if cache is not None:  # prefill: gather per-row final states
            L = dctx.lengths if dctx.lengths is not None else jnp.full(
                (x.shape[0],), x.shape[1], jnp.int32)
            last = jnp.clip(L - 1, 0, x.shape[1] - 1)
            gather = lambda seq: jnp.take_along_axis(
                seq, last[:, None, None].astype(jnp.int32), axis=1).squeeze(1)
            new_cache = {"S": S, "x_tm": gather(n1), "x_cm": gather(n2)}
        return x, new_cache, aux

    h = apply_norm_block(p["norm1"], x, cfg)
    if kind == "attn":
        if dctx.mode == "full":
            a = attention(p["attn"], cfg, h, positions=dctx.positions,
                          causal=dctx.causal,
                          padding_mask=None if dctx.lengths is None else
                          (jnp.arange(h.shape[1]) < dctx.lengths[:, None]))
            new_cache = None
        else:
            a, new_cache = cached_attention(p["attn"], cfg, h, cache, dctx.positions)
        x = x + a
    elif kind == "xattn":
        if dctx.mode == "decode":
            q = attn_mod.cached_cross_attention(p["attn"], cfg, h, cache,
                                                memory_mask=dctx.memory_mask)
            new_cache = cache
        else:
            q = cross_attention(p["attn"], cfg, h, dctx.memory,
                                memory_mask=dctx.memory_mask)
            new_cache = (attn_mod.memory_kv(p["attn"], cfg, dctx.memory)
                         if dctx.mode == "prefill" else None)
        x = x + jnp.tanh(p["xattn_gate"]) * q
    elif kind == "mamba":
        if dctx.mode == "decode":
            m_out, new_cache = _mamba_decode_ckpt(p["mamba"], cfg, cache, h)
        elif dctx.mode == "prefill":
            m_out, new_cache = mamba_mod.mamba_mixer(
                p["mamba"], cfg, h, lengths=dctx.lengths, return_state=True)
        else:
            m_out = mamba_mod.mamba_mixer(p["mamba"], cfg, h, lengths=dctx.lengths)
            new_cache = None
        x = x + m_out
    else:
        raise ValueError(kind)

    h2 = apply_norm_block(p["norm2"], x, cfg)
    f_out, aux = _apply_ffn(p["ffn"], cfg, ffn_kind, h2)
    x = x + f_out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack


# Layer-scan unroll factor. The multi-pod dry-run sets this to a full unroll:
# (a) XLA's cost analysis counts while-loop bodies once, so a rolled scan
# underreports FLOPs by ~n_repeats; (b) GSPMD hoists the FSDP all-gather of
# the *stacked* layer weights out of the loop, inflating temp memory by the
# full unsharded parameter size. Unrolled, gathers happen per layer and are
# freed. Training-time default stays rolled (compile-time friendly).
SCAN_UNROLL: int | bool = 1


def _run_stack(params, cfg: ModelConfig, x, cache, dctx: DecodeContext,
               *, remat: bool = False):
    aux_keys = ("moe_aux_loss", "moe_z_loss") if "moe" in cfg.ffn_pattern else ()

    def repeat_body(h, xs):
        p_tuple, c_tuple = xs
        new_caches = []
        aux_sum = {k: jnp.float32(0) for k in aux_keys}
        for i, kind in enumerate(cfg.layer_pattern):
            c_i = None if c_tuple is None else c_tuple[i]
            h, nc, aux = _block_apply(kind, cfg.ffn_pattern[i], p_tuple[i],
                                      cfg, h, c_i, dctx)
            h = shard_ctx.constrain_activation(h)
            new_caches.append(nc)
            for k in aux_keys:
                aux_sum[k] = aux_sum[k] + aux.get(k, 0.0)
        return h, (tuple(new_caches), aux_sum)

    body = jax.checkpoint(repeat_body) if remat else repeat_body
    x, (new_cache, aux_per_rep) = jax.lax.scan(body, x, (params["blocks"], cache),
                                               unroll=SCAN_UNROLL)
    aux = {k: jnp.sum(v) for k, v in aux_per_rep.items()}
    return x, new_cache, aux


def _embed_in(params, cfg: ModelConfig, tokens, embeddings):
    if embeddings is not None:
        return embeddings
    return embed(params["tok"], tokens)


def _logits_out(params, cfg: ModelConfig, x):
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        return unembed(params["tok"], x)
    return x @ params["lm_head"]["w_vocab"]


# ---------------------------------------------------------------------------
# public API


def apply(params, cfg: ModelConfig, tokens=None, *, embeddings=None, memory=None,
          memory_mask=None, lengths=None, positions=None, causal=None,
          remat: bool = False):
    """Full-sequence forward (training). Returns (logits, aux)."""
    x = _embed_in(params, cfg, tokens, embeddings)
    B, T = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    dctx = DecodeContext(mode="full", causal=cfg.causal if causal is None else causal,
                         memory=memory, memory_mask=memory_mask, lengths=lengths,
                         positions=positions)
    x = shard_ctx.constrain_activation(x)
    x, _, aux = _run_stack(params, cfg, x, None, dctx, remat=remat)
    return _logits_out(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, cache, tokens=None, *, embeddings=None,
            memory=None, memory_mask=None, lengths=None,
            logits_mode: str = "all"):
    """Process the prompt, filling caches. Returns (logits, cache).

    ``logits_mode="last"`` computes logits only at each row's final valid
    position — (B, V) instead of (B, T, V). At 32k prompt × 256k vocab the
    full tensor would be half a terabyte; serving never needs it.
    """
    x = _embed_in(params, cfg, tokens, embeddings)
    B, T = x.shape[:2]
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    pos = jnp.arange(T, dtype=jnp.int32)[None, :]
    positions = jnp.where(pos < lengths[:, None], pos, -1)  # pads -> masked slot
    dctx = DecodeContext(mode="prefill", causal=True, memory=memory,
                         memory_mask=memory_mask, lengths=lengths,
                         positions=positions)
    x, new_cache, _ = _run_stack(params, cfg, x, cache, dctx)
    if logits_mode == "last":
        last = jnp.clip(lengths - 1, 0, T - 1)
        x = jnp.take_along_axis(x, last[:, None, None].astype(jnp.int32),
                                axis=1)[:, 0]
        return _logits_out(params, cfg, x), new_cache
    return _logits_out(params, cfg, x), new_cache


def multidraft_verify_step(params, cfg: ModelConfig, cache, tokens, positions,
                           local_mask, *, memory_mask=None):
    """Single-pass verification of ALL drafts (beyond-paper; see
    attention.multidraft_attention). tokens: (B, 1 + N_d·DL) =
    [last_committed, draft_0…, draft_{N_d-1}…]; positions: their logical
    absolute positions; local_mask: static (T, T) segment mask.

    Attention-family blocks only (dense/MoE/VLM): recurrent mixers process
    tokens sequentially, so multi-draft segments cannot share a row —
    those archs use the expanded-batch path (DESIGN.md §4).

    Returns (logits, local_kv) where local_kv is a tuple (one per attn
    pattern position) of (k_new, v_new) stacked over scan repeats — feed it
    to ``commit_multidraft``. The cache is NOT modified.
    """
    for kind in cfg.layer_pattern:
        if kind in ("mamba", "rwkv"):
            raise NotImplementedError(
                "multidraft verification needs attention blocks; recurrent "
                "families use the expanded-batch verify path")
    x = _embed_in(params, cfg, tokens, None)

    def repeat_body(h, xs):
        p_tuple, c_tuple = xs
        kvs = []
        for i, kind in enumerate(cfg.layer_pattern):
            p = p_tuple[i]
            h1 = apply_norm_block(p["norm1"], h, cfg)
            if kind == "attn":
                a, kv = attn_mod.multidraft_attention(
                    p["attn"], cfg, h1, c_tuple[i], positions, local_mask)
                h = h + a
                kvs.append(kv)
            elif kind == "xattn":
                qo = attn_mod.cached_cross_attention(
                    p["attn"], cfg, h1, c_tuple[i], memory_mask=memory_mask)
                h = h + jnp.tanh(p["xattn_gate"]) * qo
                kvs.append((jnp.zeros((0,)), jnp.zeros((0,))))
            h2 = apply_norm_block(p["norm2"], h, cfg)
            f_out, _ = _apply_ffn(p["ffn"], cfg, cfg.ffn_pattern[i], h2)
            h = h + f_out
        return h, tuple(kvs)

    x, local_kv = jax.lax.scan(repeat_body, x,
                               (params["blocks"], cache), unroll=SCAN_UNROLL)
    return _logits_out(params, cfg, x), local_kv


def commit_multidraft(cfg: ModelConfig, cache, local_kv, best, n_acc,
                      start_pos, *, draft_len: int):
    """Write the winning draft's accepted K/V into the cache.

    best: (B,) winning draft index; n_acc: (B,) accepted draft tokens;
    start_pos: (B,) position of the fed last_committed token. Commits the
    last token + n_acc accepted draft tokens (n_keep = 1 + n_acc), exactly
    mirroring the expanded-batch invariant."""
    B = best.shape[0]
    DL = draft_len
    rel = jnp.arange(DL + 1, dtype=jnp.int32)
    # local indices: 0 (last_tok), then winner segment 1 + best*DL + i
    take_idx = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32),
         1 + best[:, None] * DL + rel[None, :-1]], axis=1)       # (B, DL+1)
    positions = start_pos[:, None] + rel[None, :]
    n_keep = 1 + n_acc
    out = []
    for kind, c, kv in zip(cfg.layer_pattern, cache, local_kv):
        if kind == "attn":
            def one(cc, kk, vv):
                return attn_mod.commit_verified_kv(cc, kk, vv, take_idx,
                                                   positions, n_keep)
            out.append(jax.vmap(one)(c, kv[0], kv[1]))
        else:
            out.append(c)
    return tuple(out)


def decode_step(params, cfg: ModelConfig, cache, tokens, positions, *,
                memory_mask=None):
    """Decode T new tokens (T = 1 for plain greedy, DL+1 for verification).

    positions: (B, T) absolute positions of the fed tokens (rows may differ).
    Returns (logits (B,T,V), cache-with-checkpoints) — call ``commit_cache``.
    """
    x = _embed_in(params, cfg, tokens, None)
    dctx = DecodeContext(mode="decode", causal=True, memory_mask=memory_mask,
                         positions=positions)
    x, new_cache, _ = _run_stack(params, cfg, x, cache, dctx)
    return _logits_out(params, cfg, x), new_cache
