"""Encoder-decoder transformer — the Molecular Transformer (Schwaller 2019).

SMILES-to-SMILES translation: encoder over reactant tokens, autoregressive
decoder with cross-attention over the encoder memory. This is the model the
paper accelerates; its decoder exposes the same ``decode_step`` contract as
``repro.models.transformer`` so the speculative decoders in ``repro.core``
work on both.

Deviations from the 2019 OpenNMT implementation (recorded per DESIGN.md §2):
pre-LN residual blocks instead of post-LN (training stability; accuracy
parity is re-validated against our own beam-search baseline, which is what
the paper itself does in Table 1), GELU instead of ReLU.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.attention import attention, cached_attention, cross_attention
from repro.models.layers import (
    apply_norm, dense, embed, embed_init, ffn, ffn_init, logits_init, norm_init,
    sinusoidal_positions,
)


# ---------------------------------------------------------------------------
# init


def _enc_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attn_mod.attn_init(k1, cfg, dtype=dtype),
        "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
        "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, use_bias=cfg.use_bias,
                        gated=cfg.gated_ffn, dtype=dtype),
    }


def _dec_block_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
        "self_attn": attn_mod.attn_init(k1, cfg, dtype=dtype),
        "norm_x": norm_init(cfg.d_model, cfg.norm, dtype),
        "cross_attn": attn_mod.attn_init(k2, cfg, cross=True, dtype=dtype),
        "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
        "ffn": ffn_init(k3, cfg.d_model, cfg.d_ff, use_bias=cfg.use_bias,
                        gated=cfg.gated_ffn, dtype=dtype),
    }


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    assert cfg.family == "seq2seq" and cfg.n_encoder_layers > 0
    k_emb, k_enc, k_dec, k_out = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "tok": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),  # shared
        "enc_blocks": jax.vmap(partial(_enc_block_init, cfg=cfg, dtype=dtype))(enc_keys),
        "enc_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "dec_blocks": jax.vmap(partial(_dec_block_init, cfg=cfg, dtype=dtype))(dec_keys),
        "dec_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "lm_head": logits_init(k_out, cfg.d_model, cfg.vocab_size, dtype),
    }


def _embed_pos(params, cfg: ModelConfig, tokens, positions):
    x = embed(params["tok"], tokens) * math.sqrt(cfg.d_model)
    pe = sinusoidal_positions(cfg.max_len, cfg.d_model, x.dtype)
    return x + pe[jnp.clip(positions, 0)]


# ---------------------------------------------------------------------------
# encoder


def encode(params, cfg: ModelConfig, src, *, src_mask=None):
    """src: (B, S) token ids; src_mask: (B, S) True=valid (default: != 0/pad).

    Returns (memory (B, S, d), src_mask).
    """
    if src_mask is None:
        src_mask = src != 0
    B, S = src.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _embed_pos(params, cfg, src, positions)

    def body(h, p):
        a = attention(p["attn"], cfg, apply_norm(p["norm1"], h, cfg.norm),
                      positions=positions, causal=False, padding_mask=src_mask)
        h = h + a
        f = ffn(p["ffn"], apply_norm(p["norm2"], h, cfg.norm))
        return h + f, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg.norm), src_mask


# ---------------------------------------------------------------------------
# decoder (full sequence — training)


def decode(params, cfg: ModelConfig, tgt_in, memory, src_mask, *, lengths=None):
    """Teacher-forced decoder pass. tgt_in: (B, T). Returns logits (B, T, V)."""
    B, T = tgt_in.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = _embed_pos(params, cfg, tgt_in, positions)
    pad_mask = None if lengths is None else (jnp.arange(T) < lengths[:, None])

    def body(h, p):
        a = attention(p["self_attn"], cfg, apply_norm(p["norm1"], h, cfg.norm),
                      positions=positions, causal=True, padding_mask=pad_mask)
        h = h + a
        c = cross_attention(p["cross_attn"], cfg, apply_norm(p["norm_x"], h, cfg.norm),
                            memory, memory_mask=src_mask)
        h = h + c
        f = ffn(p["ffn"], apply_norm(p["norm2"], h, cfg.norm))
        return h + f, None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return x @ params["lm_head"]["w_vocab"]


def apply(params, cfg: ModelConfig, src, tgt_in, *, src_mask=None, lengths=None):
    """Full training forward: returns (logits, aux={})."""
    memory, src_mask = encode(params, cfg, src, src_mask=src_mask)
    return decode(params, cfg, tgt_in, memory, src_mask, lengths=lengths), {}


# ---------------------------------------------------------------------------
# cached decode (serving) — contract shared with repro.models.transformer


def init_cache(cfg: ModelConfig, batch: int, max_len: int, memory=None,
               params=None, dtype=jnp.float32, memory_len=None,
               memory_mask=None, paged=None) -> dict:
    """Self-attn KV caches + precomputed cross K/V (if memory given).

    ``memory_len``: cross K/V width when ``memory`` is absent — the
    continuous-batching session allocates empty rows up front and scatters
    each request's memory K/V in at admission time.
    ``memory_mask``: (batch, M) True=valid; when given it is stored INSIDE
    the cache (leaf shape (1, batch, M), batch on axis 1 like every other
    leaf), so batch-row expansion/gather/scatter ops carry each row's mask
    along and ``decode_step`` needs no closed-over mask.
    ``paged``: ``(n_pages, page_size)`` — allocate the self-attn cache as a
    ``PagedKVCache`` (one pool per decoder layer) instead of dense rows; the
    caller owns page mapping (``repro.core.session.PageAllocator``). The
    cross K/V stays dense: it is fixed-size per request and written once at
    admission.
    """
    R = cfg.n_layers
    stack = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (R,) + a.shape), t)
    if paged is not None:
        n_pages, page_size = paged
        self_cache = stack(attn_mod.init_paged_kv_cache(
            cfg, batch, max_len, n_pages=n_pages, page_size=page_size,
            dtype=dtype))
    else:
        self_cache = stack(attn_mod.init_kv_cache(cfg, batch, max_len,
                                                  dtype=dtype))
    if memory is not None and params is not None:
        mkv = jax.vmap(
            lambda p: attn_mod.memory_kv(p, cfg, memory)
        )(params["dec_blocks"]["cross_attn"])
    else:
        M = (memory_len if memory_len is not None
             else (1 if memory is None else memory.shape[1]))
        mkv = stack({"mk": jnp.zeros((batch, M, cfg.n_heads, cfg.head_dim), dtype),
                     "mv": jnp.zeros((batch, M, cfg.n_heads, cfg.head_dim), dtype)})
    cache = {"self": self_cache, "cross": mkv}
    if memory_mask is not None:
        cache["mmask"] = jnp.asarray(memory_mask, bool)[None]
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens, positions, *,
                memory_mask=None):
    """Feed T new tokens (T = DL+1 for verification). Returns (logits, cache).

    ``positions``: (B, T) absolute target positions (rows may differ) — this
    is the JAX-native equivalent of the paper's padLeft + shifted positional
    encodings (DESIGN.md §2). When no explicit ``memory_mask`` is passed the
    per-row mask stored in the cache (if any) applies.
    """
    if memory_mask is None and "mmask" in cache:
        memory_mask = cache["mmask"][0]
    x = _embed_pos(params, cfg, tokens, positions)

    def body(h, xs):
        p, c_self, c_cross = xs
        a, c_self = cached_attention(
            p["self_attn"], cfg, apply_norm(p["norm1"], h, cfg.norm), c_self,
            positions)
        h = h + a
        c = attn_mod.cached_cross_attention(
            p["cross_attn"], cfg, apply_norm(p["norm_x"], h, cfg.norm), c_cross,
            memory_mask=memory_mask)
        h = h + c
        f = ffn(p["ffn"], apply_norm(p["norm2"], h, cfg.norm))
        return h + f, c_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross"]))
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    logits = x @ params["lm_head"]["w_vocab"]
    new_cache = {"self": new_self, "cross": cache["cross"]}
    if "mmask" in cache:
        new_cache["mmask"] = cache["mmask"]
    return logits, new_cache


def commit_cache(cfg: ModelConfig, cache, n_keep):
    """KV caches need no rollback (stale slots are overwritten; see
    repro.models.transformer docstring)."""
    return cache
