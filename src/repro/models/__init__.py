from repro.models import transformer, seq2seq

__all__ = ["transformer", "seq2seq"]
