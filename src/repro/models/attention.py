"""Grouped-query attention with a unified KV cache.

One attention implementation serves every mode in the framework:

  - full-sequence causal (training / prefill), optional sliding window
  - bidirectional (encoder-only: HuBERT, MT encoder)
  - cross-attention to a memory (MT decoder, VLM image layers)
  - cached decode with per-row absolute positions — the speculative-decoding
    verify pass feeds ``DL+1`` tokens per sequence in one call

KV-cache design (TPU-native): pre-allocated ``(B, S, n_kv, head_dim)`` buffers
plus a ``(B, S)`` int32 ``pos`` array holding the *absolute* position stored in
each slot (-1 = empty). Writes go to ``slot = position % S``; masking is done
on stored positions, which makes a ring buffer (sliding window, ``S = window``)
and a linear cache (``S = max_len``) the same code path.

Paged variant (serving): ``PagedKVCache`` replaces the per-row ``(B, S)``
reservation with a global page pool ``(n_pages, page_size, n_kv, head_dim)``
plus a per-row block table ``(B, n_blocks)`` of page ids (-1 = unmapped).
Rows of one request share read-only committed pages (the host allocator in
``repro.core.session.PageAllocator`` copy-on-writes the draft-boundary page),
so HBM scales with *live tokens*, not ``n_rows * max_len``. Page 0 is a
reserved trash page: writes whose target block is unmapped (or whose position
is -1) land there with stored position -1, so they are never attended to.
Masking semantics are identical to the dense cache — stored positions are the
single source of truth — which is what makes paged and dense decoding
token-identical (``tests/test_session.py``).
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, apply_rope, dense, dense_init

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params


def attn_init(key, cfg: ModelConfig, *, cross: bool = False, dtype=jnp.float32) -> dict:
    """Attention projections. ``cross=True`` reads K/V from memory_dim."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    kv_src = cfg.memory_dim if (cross and cfg.memory_dim) else d
    n_kv = cfg.n_heads if cross else cfg.n_kv_heads  # cross-attn: MHA over memory
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, use_bias=cfg.use_bias, dtype=dtype),
        "wk": dense_init(kk, kv_src, n_kv * hd, use_bias=cfg.use_bias, dtype=dtype),
        "wv": dense_init(kv, kv_src, n_kv * hd, use_bias=cfg.use_bias, dtype=dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, use_bias=cfg.use_bias, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype)}
    return p


# ---------------------------------------------------------------------------
# cache


@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray    # (B, S, n_kv, head_dim)
    v: jnp.ndarray    # (B, S, n_kv, head_dim)
    pos: jnp.ndarray  # (B, S) int32, absolute position stored in slot, -1 empty


jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v", "pos"], meta_fields=[])


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *, cross: bool = False,
                  dtype=jnp.float32) -> KVCache:
    n_kv = cfg.n_heads if cross else cfg.n_kv_heads
    size = max_len if (cfg.sliding_window == 0 or cross) else min(max_len, cfg.sliding_window)
    return KVCache(
        k=jnp.zeros((batch, size, n_kv, cfg.head_dim), dtype),
        v=jnp.zeros((batch, size, n_kv, cfg.head_dim), dtype),
        pos=jnp.full((batch, size), -1, jnp.int32),
    )


def _write_cache(cache: KVCache, k_new, v_new, positions) -> KVCache:
    """Scatter new K/V at ``slot = position % S``; positions: (B, T)."""
    S = cache.k.shape[1]
    b_idx = jnp.arange(cache.k.shape[0])[:, None]
    slots = positions % S
    return KVCache(
        k=cache.k.at[b_idx, slots].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[b_idx, slots].set(v_new.astype(cache.v.dtype)),
        pos=cache.pos.at[b_idx, slots].set(positions.astype(jnp.int32)),
    )


# ---------------------------------------------------------------------------
# paged cache


TRASH_PAGE = 0  # reserved: writes with no mapped target land here, pos = -1

# Opt-in fast read path: the Pallas block-table kernel replaces paged_view's
# materialized (B, n_blocks*ps, ...) gather in cached_attention. Off by
# default — the XLA view is the reference. The flag is read at trace time,
# so flipping it after a function has been jitted means a retrace, not a
# silent no-op; flip it before warmup.
_PAGED_KERNEL = os.environ.get("REPRO_PAGED_KERNEL", "") not in ("", "0", "false")


def use_paged_kernel(enabled: bool = True) -> None:
    global _PAGED_KERNEL
    _PAGED_KERNEL = bool(enabled)


def paged_kernel_enabled() -> bool:
    return _PAGED_KERNEL


@dataclasses.dataclass
class PagedKVCache:
    """Block-table KV cache: a global page pool shared by all batch rows.

    ``block_tables[b, j]`` maps logical block ``j`` of row ``b`` to a page in
    the pool (-1 = unmapped). Logical position ``p`` of row ``b`` lives at
    ``(page=block_tables[b, (p // ps) % n_blocks], slot=p % ps)``. The pool
    (and stored positions) carry no batch axis, so batch-row ops — beam
    reorder, winner sync, slot recycling — touch ONLY the tiny block tables;
    page contents are shared by aliasing. The host allocator keeps the
    invariant that pages overlapping a row's write window ``[pos, pos+DL]``
    are privately owned (copy-on-write at the draft boundary).

    Cross-request prefix sharing (``repro.core.session.RadixPageCache``)
    adds one more aliasing form: a committed PROMPT page may be referenced
    by rows of SEVERAL requests, plus one reserved index-row cell that
    keeps it allocated after every owner leaves. The invariants that make
    this safe:

      - shared pages are read-only by construction — a decode write window
        starts at the prompt's final token, strictly above every fully
        committed prompt block, and prefix matches are truncated to full
        pages, so no lane ever writes into an aliased prefix page;
      - both page planners (the host walk and the on-device plan) elect a
        page's writer as its copy-on-write *keeper* only when that row
        holds the page's ONLY references — an extra reference from another
        request's row or from a radix index cell forces the writer to copy
        first, never to mutate in place;
      - attention masks on STORED positions, so which physical page backs
        a block never affects output — aliased and privately-owned reads
        are bitwise identical.
    """

    k_pool: jnp.ndarray        # (P, ps, n_kv, head_dim)
    v_pool: jnp.ndarray        # (P, ps, n_kv, head_dim)
    pos: jnp.ndarray           # (P, ps) int32, absolute position stored, -1 empty
    block_tables: jnp.ndarray  # (B, n_blocks) int32 page id, -1 unmapped

    @property
    def page_size(self) -> int:
        return self.k_pool.shape[-3]

    @property
    def n_blocks(self) -> int:
        return self.block_tables.shape[-1]


jax.tree_util.register_dataclass(
    PagedKVCache, data_fields=["k_pool", "v_pool", "pos", "block_tables"],
    meta_fields=[])


def init_paged_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                        n_pages: int, page_size: int, cross: bool = False,
                        dtype=jnp.float32) -> PagedKVCache:
    """Empty pool + unmapped tables. ``n_blocks`` covers the same logical
    length the dense cache would reserve per row (ring over blocks when a
    sliding window applies); page 0 is the reserved trash page."""
    n_kv = cfg.n_heads if cross else cfg.n_kv_heads
    size = max_len if (cfg.sliding_window == 0 or cross) else min(max_len, cfg.sliding_window)
    n_blocks = -(-size // page_size)
    if n_pages < 2:
        raise ValueError("n_pages must be >= 2 (page 0 is the trash page)")
    return PagedKVCache(
        k_pool=jnp.zeros((n_pages, page_size, n_kv, cfg.head_dim), dtype),
        v_pool=jnp.zeros((n_pages, page_size, n_kv, cfg.head_dim), dtype),
        pos=jnp.full((n_pages, page_size), -1, jnp.int32),
        block_tables=jnp.full((batch, n_blocks), -1, jnp.int32),
    )


def _lookup_pages(cache: PagedKVCache, positions):
    """positions (B, T) -> (page (B, T), slot (B, T), mapped (B, T))."""
    ps, nb = cache.page_size, cache.n_blocks
    blocks = (positions // ps) % nb
    b_idx = jnp.arange(cache.block_tables.shape[0])[:, None]
    page = cache.block_tables[b_idx, blocks]
    mapped = (page >= 0) & (positions >= 0)
    return jnp.where(mapped, page, TRASH_PAGE), positions % ps, mapped


def _write_cache_paged(cache: PagedKVCache, k_new, v_new, positions
                       ) -> PagedKVCache:
    """Scatter new K/V through the block table; positions: (B, T). Invalid
    targets (position -1 or unmapped block) go to the trash page with stored
    position -1 — unreadable, exactly like the dense pad convention."""
    page, slot, mapped = _lookup_pages(cache, positions)
    store_pos = jnp.where(mapped, positions, -1).astype(jnp.int32)
    return dataclasses.replace(
        cache,
        k_pool=cache.k_pool.at[page, slot].set(k_new.astype(cache.k_pool.dtype)),
        v_pool=cache.v_pool.at[page, slot].set(v_new.astype(cache.v_pool.dtype)),
        pos=cache.pos.at[page, slot].set(store_pos),
    )


def paged_view(cache: PagedKVCache):
    """Materialize the dense per-row view (k, v, kpos) the attention math
    expects: (B, n_blocks*ps, n_kv, hd) x2 + (B, n_blocks*ps) positions.
    Unmapped blocks read the trash page but are masked to position -1. This
    is the XLA reference read path; the Pallas kernel
    (``repro.kernels.decode_gqa.paged_decode_gqa_attention``) walks the block
    table instead and never materializes the gather."""
    B, nb = cache.block_tables.shape
    ps = cache.page_size
    pages = jnp.where(cache.block_tables >= 0, cache.block_tables, TRASH_PAGE)
    k = cache.k_pool[pages].reshape(B, nb * ps, *cache.k_pool.shape[2:])
    v = cache.v_pool[pages].reshape(B, nb * ps, *cache.v_pool.shape[2:])
    kpos = jnp.where(cache.block_tables[..., None] >= 0, cache.pos[pages], -1)
    return k, v, kpos.reshape(B, nb * ps)


# ---------------------------------------------------------------------------
# core score/combine


def _gqa_attend(q, k, v, mask, *, q_per_kv: int):
    """q: (B,T,Hq,hd); k,v: (B,S,Kv,hd); mask: broadcastable (B,1,1,T,S)."""
    B, T, Hq, hd = q.shape
    Kv = k.shape[2]
    q = q.reshape(B, T, Kv, q_per_kv, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(mask, scores, _NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v)
    return out.reshape(B, T, Hq, hd)


def _project_qkv(p: dict, cfg: ModelConfig, x, kv_input, *, cross: bool):
    B, T = x.shape[:2]
    hd = cfg.head_dim
    n_kv = cfg.n_heads if cross else cfg.n_kv_heads
    q = dense(p["wq"], x).reshape(B, T, cfg.n_heads, hd)
    k = dense(p["wk"], kv_input).reshape(B, kv_input.shape[1], n_kv, hd)
    v = dense(p["wv"], kv_input).reshape(B, kv_input.shape[1], n_kv, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    return q, k, v


# ---------------------------------------------------------------------------
# modes


# q-chunk size above which exact blockwise attention kicks in: keeps the
# (B, H, Tq, S) score tensor off HBM for 32k prompts (flash-style memory
# behaviour at the XLA level; the Pallas kernel is the TPU fast path).
_Q_CHUNK = 1024


def _masked_attend(q, k, v, qp, kp_valid, kp, *, causal, window, q_per_kv):
    """Score q rows (positions qp) against keys (positions kp, validity
    kp_valid); lazy mask construction so callers can chunk the q axis."""
    B, Tq = q.shape[:2]
    mask = kp_valid[:, None, :]
    if causal:
        mask = mask & (kp[:, None, :] <= qp[:, :, None])
        if window > 0:
            mask = mask & (kp[:, None, :] > qp[:, :, None] - window)
    return _gqa_attend(q, k, v, mask[:, None, None], q_per_kv=q_per_kv)


def _attend_maybe_chunked(q, k, v, qp, kp_valid, kp, *, causal, window,
                          q_per_kv):
    """Exact attention; scans q chunks when Tq is long so the per-step score
    tensor is (B, H, chunk, S) instead of (B, H, Tq, S)."""
    B, Tq = q.shape[:2]
    if Tq <= _Q_CHUNK or Tq % _Q_CHUNK != 0:
        return _masked_attend(q, k, v, qp, kp_valid, kp, causal=causal,
                              window=window, q_per_kv=q_per_kv)
    n = Tq // _Q_CHUNK
    q_c = q.reshape(B, n, _Q_CHUNK, *q.shape[2:]).swapaxes(0, 1)
    qp_c = qp.reshape(B, n, _Q_CHUNK).swapaxes(0, 1)

    def body(_, inp):
        qi, qpi = inp
        out = _masked_attend(qi, k, v, qpi, kp_valid, kp, causal=causal,
                             window=window, q_per_kv=q_per_kv)
        return None, out

    _, outs = jax.lax.scan(body, None, (q_c, qp_c))
    return outs.swapaxes(0, 1).reshape(B, Tq, *q.shape[2:])


def attention(p: dict, cfg: ModelConfig, x, *, positions=None, causal: bool = True,
              padding_mask=None) -> jnp.ndarray:
    """Full-sequence self-attention (training / prefill, no cache).

    x: (B, T, d); positions: (B, T) absolute; padding_mask: (B, T) True=valid.
    Sliding window applies when cfg.sliding_window > 0 and causal.
    """
    B, T = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    q, k, v = _project_qkv(p, cfg, x, x, cross=False)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kp_valid = (jnp.ones((B, T), bool) if padding_mask is None
                else padding_mask)
    out = _attend_maybe_chunked(q, k, v, positions, kp_valid, positions,
                                causal=causal, window=cfg.sliding_window,
                                q_per_kv=cfg.q_per_kv)
    return dense(p["wo"], out.reshape(B, T, -1))


def cross_attention(p: dict, cfg: ModelConfig, x, memory, *, memory_mask=None) -> jnp.ndarray:
    """x: (B, T, d) queries; memory: (B, M, memory_dim or d)."""
    B, T = x.shape[:2]
    q, k, v = _project_qkv(p, cfg, x, memory, cross=True)
    mask = jnp.ones((B, T, memory.shape[1]), bool)
    if memory_mask is not None:
        mask &= memory_mask[:, None, :]
    out = _gqa_attend(q, k, v, mask[:, None, None], q_per_kv=1)
    return dense(p["wo"], out.reshape(B, T, -1))


def memory_kv(p: dict, cfg: ModelConfig, memory) -> dict:
    """Precompute cross-attention K/V from frontend memory (prefill-time)."""
    B, M = memory.shape[:2]
    hd = cfg.head_dim
    k = dense(p["wk"], memory).reshape(B, M, cfg.n_heads, hd)
    v = dense(p["wv"], memory).reshape(B, M, cfg.n_heads, hd)
    if cfg.qk_norm:
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    return {"mk": k, "mv": v}


def cached_cross_attention(p: dict, cfg: ModelConfig, x, cache: dict,
                           *, memory_mask=None) -> jnp.ndarray:
    """Cross-attention against precomputed memory K/V (decode-time)."""
    B, T = x.shape[:2]
    hd = cfg.head_dim
    q = dense(p["wq"], x).reshape(B, T, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
    mask = jnp.ones((B, T, cache["mk"].shape[1]), bool)
    if memory_mask is not None:
        mask &= memory_mask[:, None, :]
    out = _gqa_attend(q, cache["mk"], cache["mv"], mask[:, None, None], q_per_kv=1)
    return dense(p["wo"], out.reshape(B, T, -1))


def multidraft_attention(p: dict, cfg: ModelConfig, x, cache: KVCache,
                         positions, local_mask):
    """Single-pass multi-draft verification attention (beyond-paper;
    DESIGN.md §2 / EXPERIMENTS.md §Perf).

    The paper verifies N_d drafts by inflating the batch to B·N_d — every
    draft row re-reads the whole KV cache. Here ONE row per sequence feeds
    all drafts: x = (B, T_local, d) with T_local = 1 + N_d·DL (last committed
    token + the drafts back-to-back); ``local_mask`` (T_local, T_local) is
    the static segment mask (token (j,i) sees token 0 and its own draft's
    prefix). Fed tokens attend jointly (one softmax) over:
      - the committed cache (READ ONCE per sequence — the N_d× saving), and
      - the local K/V of their own segment.
    Nothing is written to the cache; the caller commits the winning draft's
    accepted K/V afterwards (transformer.commit_verified).

    Returns (out (B, T_local, d), (k_new, v_new)) — local K/V for commit.
    """
    B, T = x.shape[:2]
    q, k_new, v_new = _project_qkv(p, cfg, x, x, cross=False)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    # cache part: committed entries only (invariant: cache holds committed
    # tokens < min(positions); no stale slots in the multidraft flow)
    kp = cache.pos[:, None, :]
    qp = positions[:, :, None]
    cache_mask = (kp >= 0) & (kp <= qp)
    if cfg.sliding_window > 0:
        cache_mask &= kp > qp - cfg.sliding_window
    # Two-part joint softmax (NO concatenation: concatenating (S + T_local)
    # keys copies the cache every layer and breaks its sequence sharding —
    # GSPMD then all-gathers the whole cache per layer; observed 126× worse
    # collective term before this formulation).
    Kv = cache.k.shape[2]
    G = cfg.q_per_kv
    hd = cfg.head_dim
    qh = q.reshape(B, T, Kv, G, hd)
    scale = 1.0 / math.sqrt(hd)
    s_c = jnp.einsum("btkgh,bskh->bkgts", qh, cache.k).astype(jnp.float32) * scale
    s_l = jnp.einsum("btkgh,bskh->bkgts", qh, k_new).astype(jnp.float32) * scale
    s_c = jnp.where(cache_mask[:, None, None], s_c, _NEG_INF)
    s_l = jnp.where(local_mask[None, None, None], s_l, _NEG_INF)
    m = jnp.maximum(jnp.max(s_c, axis=-1, keepdims=True),
                    jnp.max(s_l, axis=-1, keepdims=True))
    p_c = jnp.exp(s_c - m)
    p_l = jnp.exp(s_l - m)
    denom = (jnp.sum(p_c, axis=-1, keepdims=True)
             + jnp.sum(p_l, axis=-1, keepdims=True))
    p_c = (p_c / denom).astype(cache.v.dtype)
    p_l = (p_l / denom).astype(v_new.dtype)
    out = (jnp.einsum("bkgts,bskh->btkgh", p_c, cache.v)
           + jnp.einsum("bkgts,bskh->btkgh", p_l, v_new)).reshape(B, T, -1)
    return dense(p["wo"], out), (k_new, v_new)


def commit_verified_kv(cache: KVCache, k_new, v_new, take_idx, positions,
                       n_keep) -> KVCache:
    """Write the winning draft's accepted K/V into the cache.

    take_idx: (B, W) local indices of [last_tok, winning draft tokens];
    positions: (B, W) their absolute positions; n_keep: (B,) how many of the
    W are committed (the rest are written with stored position -1, i.e.
    invalid — their slots are re-written by the next commit before any
    query can see them)."""
    b = jnp.arange(take_idx.shape[0])[:, None]
    k_sel = k_new[b, take_idx]
    v_sel = v_new[b, take_idx]
    W = take_idx.shape[1]
    valid = jnp.arange(W)[None, :] < n_keep[:, None]
    S = cache.k.shape[1]
    slots = positions % S  # slot from the position; stored pos marks validity
    return KVCache(
        k=cache.k.at[b, slots].set(k_sel.astype(cache.k.dtype)),
        v=cache.v.at[b, slots].set(v_sel.astype(cache.v.dtype)),
        pos=cache.pos.at[b, slots].set(
            jnp.where(valid, positions, -1).astype(jnp.int32)),
    )


def cached_attention(p: dict, cfg: ModelConfig, x, cache, positions,
                     ) -> tuple[jnp.ndarray, Any]:
    """Cached causal decode (and prefill-into-cache), dense or paged.

    x: (B, T, d) new tokens; positions: (B, T) absolute positions of those
    tokens (rows may differ — the speculative decoder relies on this).
    Pad-token convention: ``positions == -1`` marks invalid tokens; their K/V
    land in a throwaway slot with stored position -1, which every query masks.
    ``cache`` is a ``KVCache`` or a ``PagedKVCache`` — masking semantics are
    identical, so the two produce the same output for the same stored tokens.
    Returns output (B, T, d) and the updated cache.
    """
    B, T = x.shape[:2]
    q, k_new, v_new = _project_qkv(p, cfg, x, x, cross=False)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    if isinstance(cache, PagedKVCache):
        cache = _write_cache_paged(cache, k_new, v_new, positions)
        if _PAGED_KERNEL:
            # Lazy import: models must not depend on the kernels package
            # unless the fast path is actually enabled.
            from repro.kernels.decode_gqa import paged_decode_gqa_attention
            out = paged_decode_gqa_attention(
                q, cache.k_pool, cache.v_pool, cache.pos,
                cache.block_tables, positions,
                window=cfg.sliding_window,
                interpret=jax.default_backend() != "tpu")
            return dense(p["wo"], out.reshape(B, T, -1)), cache
        k, v, kpos = paged_view(cache)
    else:
        cache = _write_cache(cache, k_new, v_new, positions)
        k, v, kpos = cache.k, cache.v, cache.pos
    out = _attend_maybe_chunked(
        q, k, v, positions, kpos >= 0, kpos,
        causal=True, window=cfg.sliding_window, q_per_kv=cfg.q_per_kv)
    return dense(p["wo"], out.reshape(B, T, -1)), cache
