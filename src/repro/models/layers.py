"""Parameter-init and primitive layers shared by all model families.

Params are plain nested dicts of jnp arrays (pure-functional, no flax).
Naming conventions are load-bearing: ``repro.sharding.rules`` assigns
PartitionSpecs from leaf path names (``embed``, ``wq``, ``w_in`` …).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, d_in: int, d_out: int, *, use_bias: bool, dtype=jnp.float32,
               scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, kind: str, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# positions


def rope_frequencies(head_dim: int, theta: float, dtype=jnp.float32) -> jnp.ndarray:
    half = head_dim // 2
    return (1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))).astype(dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, head_dim); positions: broadcastable to (..., T)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                 # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]                       # (..., T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d_model: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d_model, 2, dtype=jnp.float32)
                  * (-math.log(10_000.0) / d_model))
    pe = jnp.zeros((max_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


# ---------------------------------------------------------------------------
# FFN


def ffn_init(key, d_model: int, d_ff: int, *, use_bias: bool, gated: bool = True,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(k1, d_model, d_ff, use_bias=use_bias, dtype=dtype),
        "w_out": dense_init(k2, d_ff, d_model, use_bias=use_bias, dtype=dtype),
    }
    if gated:
        p["w_gate"] = dense_init(k3, d_model, d_ff, use_bias=use_bias, dtype=dtype)
    return p


def ffn(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = dense(p["w_in"], x)
    if "w_gate" in p:
        h = jax.nn.silu(dense(p["w_gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    return dense(p["w_out"], h)


# ---------------------------------------------------------------------------
# embedding


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"embed": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["embed"][tokens]


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["embed"].T


def logits_init(key, d_model: int, vocab: int, dtype=jnp.float32) -> dict:
    return {"w_vocab": (jax.random.normal(key, (d_model, vocab))
                        * (1.0 / math.sqrt(d_model))).astype(dtype)}
