"""Mamba (selective SSM) mixer — the recurrent half of Jamba's 1:7 interleave.

Faithful to Mamba-1 (Gu & Dao 2023) as used by Jamba (arXiv:2403.19887):
  x -> in-proj to (x, z) of width d_inner = expand*d_model
    -> depthwise causal conv (d_conv)  -> silu
    -> selective SSM: h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t ;  y_t = C_t h_t + D x_t
    -> y * silu(z) -> out-proj

TPU adaptation: the recurrence is evaluated with ``jax.lax.associative_scan``
over the binary operator on (decay, increment) pairs — O(log T) depth on the
VPU instead of a sequential scan — for train/prefill, and a single fused state
update for decode. The scan-over-time form keeps the HLO size independent of
sequence length, which is what lets the 524k-token ``long_500k`` shape lower.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, dense_init


def _dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = max(1, math.ceil(cfg.d_model / 16))
    return d_inner, m.d_state, m.d_conv, dt_rank


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d_inner, d_state, d_conv, dt_rank = _dims(cfg)
    keys = jax.random.split(key, 6)
    p = {
        "w_in": dense_init(keys[0], cfg.d_model, 2 * d_inner, use_bias=False, dtype=dtype),
        "conv_w": (jax.random.normal(keys[1], (d_conv, d_inner)) / math.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        # selective projections: x -> (Δ_rank, B, C)
        "w_xdbc": dense_init(keys[2], d_inner, dt_rank + 2 * d_state, use_bias=False, dtype=dtype),
        "w_dt": dense_init(keys[3], dt_rank, d_inner, use_bias=True, dtype=dtype),
        # A log-parameterized negative-real; D skip
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(keys[4], d_inner, cfg.d_model, use_bias=False, dtype=dtype),
    }
    return p


def _conv_full(p, x):  # x: (B, T, d_inner), causal depthwise conv
    d_conv = p["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * p["conv_w"][i] for i in range(d_conv))
    return out + p["conv_b"]


def _ssm_inputs(p, xc):
    """xc: (B, T, d_inner) post-conv activations -> Δ, B, C (selective)."""
    d_state = p["A_log"].shape[1]
    dt_rank = p["w_xdbc"]["w"].shape[1] - 2 * d_state
    dbc = dense(p["w_xdbc"], xc)
    dt, Bsel, Csel = jnp.split(dbc, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dense(p["w_dt"], dt))              # (B, T, d_inner)
    return dt, Bsel, Csel                                    # Bsel/Csel: (B, T, d_state)


def _scan_ssm(p, xc, valid=None):
    """Associative scan over h_t = a_t * h_{t-1} + b_t (per d_inner × d_state).

    ``valid`` (B, T) masks pad steps to identity updates (a=1, b=0), so the
    final state equals the state at each row's true end — what prefill needs.
    Returns (y, h_final).
    """
    dt, Bsel, Csel = _ssm_inputs(p, xc)
    if valid is not None:
        dt = dt * valid[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (d_inner, d_state)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)      # (B, T, d_inner, d_state)
    b = (dt * xc).astype(jnp.float32)[..., None] * Bsel.astype(jnp.float32)[..., None, :]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = jnp.einsum("btds,bts->btd", h, Csel.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    return y.astype(xc.dtype), h[:, -1]


def mamba_mixer(p: dict, cfg: ModelConfig, x, *, lengths=None,
                return_state: bool = False):
    """Full-sequence (train/prefill) Mamba mixer. x: (B, T, d_model).

    With ``return_state`` also returns the decode cache at each row's end
    (conv window of the last d_conv-1 real inputs + final SSM state).
    """
    d_inner = p["conv_b"].shape[0]
    d_conv = p["conv_w"].shape[0]
    B, T = x.shape[:2]
    xz = dense(p["w_in"], x)
    xi, z = jnp.split(xz, [d_inner], axis=-1)
    valid = None
    if lengths is not None:
        valid = jnp.arange(T) < lengths[:, None]
    xc = jax.nn.silu(_conv_full(p, xi))
    y, h_final = _scan_ssm(p, xc, valid)
    out = dense(p["w_out"], y * jax.nn.silu(z))
    if not return_state:
        return out
    # conv state: last d_conv-1 *real* inputs per row (right-padded batch)
    L = lengths if lengths is not None else jnp.full((B,), T, jnp.int32)
    idx = L[:, None] - (d_conv - 1) + jnp.arange(d_conv - 1)[None, :]  # (B, d_conv-1)
    take = jnp.take_along_axis(
        jnp.pad(xi, ((0, 0), (d_conv - 1, 0), (0, 0))),
        (idx + d_conv - 1).clip(0)[:, :, None].astype(jnp.int32), axis=1)
    cache = {"conv": take.astype(xi.dtype), "ssm": h_final}
    return out, cache


# ---------------------------------------------------------------------------
# decode (stateful)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    d_inner, d_state, d_conv, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_step(p: dict, cfg: ModelConfig, cache: dict, x) -> tuple[jnp.ndarray, dict]:
    """Decode T new tokens sequentially. x: (B, T, d_model)."""
    d_inner = p["conv_b"].shape[0]
    d_conv = p["conv_w"].shape[0]
    xz = dense(p["w_in"], x)
    xi, z = jnp.split(xz, [d_inner], axis=-1)

    def step(carry, xt):  # xt: (B, d_inner)
        conv_state, h = carry
        window = jnp.concatenate([conv_state, xt[:, None, :]], axis=1)  # (B,d_conv,d)
        xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
        xc = jax.nn.silu(xc)
        dt, Bsel, Csel = _ssm_inputs(p, xc[:, None, :])
        dt, Bsel, Csel = dt[:, 0], Bsel[:, 0], Csel[:, 0]
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)              # (B,d,s)
        b = (dt * xc).astype(jnp.float32)[..., None] * Bsel.astype(jnp.float32)[:, None, :]
        h = a * h + b
        y = jnp.einsum("bds,bs->bd", h, Csel.astype(jnp.float32))
        y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
        return (window[:, 1:, :], h), y.astype(x.dtype)

    (conv_state, h), ys = jax.lax.scan(
        step, (cache["conv"], cache["ssm"]), jnp.swapaxes(xi, 0, 1)
    )
    y = jnp.swapaxes(ys, 0, 1)
    out = dense(p["w_out"], y * jax.nn.silu(z))
    return out, {"conv": conv_state, "ssm": h}
